"""serve-rng: host RNG on the serving loop's host path.

The fused serving step samples on device with counter-based PRNG keys
(`runtime/sampling.py`): key = fold_in(fold_in(PRNGKey(seed), rid),
counter), a pure function of the request and the emission index. That
is what makes seeded serves replay token-identically across batch
composition, prefix-cache on/off, TP mesh sizes, and the
generate()/serve() split — and it only holds if NO host-side code in
the serve path consumes randomness of its own. The regression class
this rule guards against:

  * `np.random.*` / stdlib `random.*` anywhere on the host path —
    host RNG state makes outputs depend on call order, which batch
    composition and scheduling change freely;
  * per-step `jax.random.split` on the host path — the classic
    key-threading pattern couples each token's key to how many steps
    ran before it, so prefix-cache hits or different chunking change
    every subsequent sample (and the host->device key upload breaks
    the one-buffer-per-step dispatch contract).

Scope: non-traced functions of the serve front ends
(`repro.api.engine`, `repro.launch.serve`) and of any file marked
`# iteralint: host-serve-loop`. Traced functions are exempt — keyed
`jax.random.*` calls inside the jitted step are exactly the sanctioned
pattern. `jax.random.PRNGKey` at build time is fine (it is
per-request, not per-step); only `split` threads state.
"""
from __future__ import annotations

import ast

from tools.iteralint.framework import Analyzer, import_table, resolves_to

SERVE_MODULES = {"repro.api.engine", "repro.launch.serve"}
MARKER = "host-serve-loop"


def _own_calls(fn_node):
    """Call nodes lexically inside `fn_node` but not inside a nested
    def/lambda (nested functions are separate call-graph nodes and are
    checked under their own qual)."""
    body = fn_node.body
    stack = list(body) if isinstance(body, list) else [body]
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Call):
            yield node
        stack.extend(ast.iter_child_nodes(node))


class ServeRngAnalyzer(Analyzer):

    name = "serve-rng"
    description = ("host RNG (np.random / random.*) or per-step "
                   "jax.random.split on the serve loop's host path — "
                   "sampling must stay on device with counter-based keys")

    def run(self, project):
        graph = project.callgraph()
        traced = graph.traced()
        findings = []
        analysis = set(project.analysis_rels)
        for qual in sorted(graph.functions):
            fi = graph.functions[qual]
            sf = fi.sf
            if sf.rel not in analysis:
                continue
            if sf.module not in SERVE_MODULES \
                    and MARKER not in sf.file_markers:
                continue
            if qual in traced:
                continue        # in-device keyed PRNG is the point
            table = getattr(sf, "imports", None)
            if table is None:
                table = sf.imports = import_table(sf.tree)
            fname = qual.split(":", 1)[1]
            for call in _own_calls(fi.node):
                f = call.func
                if resolves_to(table, f, "numpy.random"):
                    findings.append(self.finding(
                        sf, call,
                        f"`{ast.unparse(f)}` host RNG in serve host-path "
                        f"function `{fname}` — sample on device with "
                        "counter-based keys (runtime/sampling.py)"))
                elif resolves_to(table, f, "random"):
                    findings.append(self.finding(
                        sf, call,
                        f"stdlib `{ast.unparse(f)}` host RNG in serve "
                        f"host-path function `{fname}` — sample on device "
                        "with counter-based keys (runtime/sampling.py)"))
                elif resolves_to(table, f, "jax.random.split"):
                    findings.append(self.finding(
                        sf, call,
                        f"per-step `jax.random.split` in serve host-path "
                        f"function `{fname}` — key threading couples "
                        "tokens to step count; derive keys in-device via "
                        "fold_in(seed, rid, counter)"))
        return findings
