"""pytree-aux: registered pytrees must keep aux_data static & hashable.

jit caches key on the aux treedef: an array in aux defeats tracing
(every step is a cache miss — or worse, a stale constant baked into the
trace), and an unhashable aux (list/dict) raises at dispatch. The
serving stack's QuantizedTensor contract is exactly "arrays in
children, static ints/bools in aux".

Checked at every `register_pytree_node` / `register_pytree_with_keys`
call where the flatten function is visible in the same file:

  * aux elements that are attribute reads of the registered class are
    resolved against the class's annotations — Array/ndarray-annotated
    fields in aux are flagged;
  * aux elements that are list/dict/set literals (or list()/dict()/
    set() calls) are flagged as unhashable.
"""
from __future__ import annotations

import ast
import re

from tools.iteralint.framework import Analyzer, dotted_name

REGISTER_FNS = {"register_pytree_node", "register_pytree_with_keys",
                "register_pytree_node_class",
                "register_pytree_with_keys_class"}
ARRAY_ANN_RE = re.compile(
    r"\b(jax\.Array|Array|jnp\.ndarray|np\.ndarray|ndarray|ArrayLike)\b")
UNHASHABLE_ANN_RE = re.compile(r"\b(list|dict|set|List|Dict|Set)\b")
UNHASHABLE_CALLS = {"list", "dict", "set", "bytearray"}


def _class_annotations(tree, cls_name):
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == cls_name:
            anns = {}
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and isinstance(
                        stmt.target, ast.Name):
                    anns[stmt.target.id] = ast.unparse(stmt.annotation)
            return anns
    return {}


def _flatten_aux_expr(flatten):
    """The aux expression of a flatten callable: second element of the
    returned pair."""
    if isinstance(flatten, ast.Lambda):
        body = flatten.body
        if isinstance(body, ast.Tuple) and len(body.elts) == 2:
            return body.elts[1]
    if isinstance(flatten, ast.FunctionDef):
        for node in ast.walk(flatten):
            if isinstance(node, ast.Return) and isinstance(
                    node.value, ast.Tuple) and len(node.value.elts) == 2:
                return node.value.elts[1]
    return None


class PytreeAuxAnalyzer(Analyzer):

    name = "pytree-aux"
    description = ("registered pytrees must not carry arrays or "
                   "unhashable values in aux_data")

    def run(self, project):
        findings = []
        for sf in project.analysis_files:
            local_defs = {n.name: n for n in ast.walk(sf.tree)
                          if isinstance(n, ast.FunctionDef)}
            for call in ast.walk(sf.tree):
                if not isinstance(call, ast.Call):
                    continue
                dn = dotted_name(call.func)
                if dn is None or dn.split(".")[-1] not in REGISTER_FNS:
                    continue
                if len(call.args) < 2:
                    continue
                cls = dotted_name(call.args[0]) or "?"
                flatten = call.args[1]
                if isinstance(flatten, ast.Name):
                    flatten = local_defs.get(flatten.id)
                aux = _flatten_aux_expr(flatten)
                if aux is None:
                    continue
                self._check_aux(sf, call, cls, aux, findings)
        return findings

    def _check_aux(self, sf, call, cls, aux, findings):
        anns = _class_annotations(sf.tree, cls.split(".")[-1])
        elts = aux.elts if isinstance(aux, (ast.Tuple, ast.List)) else [aux]
        for e in elts:
            if isinstance(e, (ast.List, ast.Dict, ast.Set)):
                findings.append(self.finding(
                    sf, e,
                    f"pytree `{cls}` aux_data contains an unhashable "
                    "literal — aux must be hashable (jit cache key)"))
                continue
            if isinstance(e, ast.Call):
                fdn = dotted_name(e.func)
                if fdn and fdn.split(".")[-1] in UNHASHABLE_CALLS:
                    findings.append(self.finding(
                        sf, e,
                        f"pytree `{cls}` aux_data calls "
                        f"`{fdn.split('.')[-1]}()` — aux must be hashable"))
                continue
            if isinstance(e, ast.Attribute):
                ann = anns.get(e.attr)
                if ann and ARRAY_ANN_RE.search(ann):
                    findings.append(self.finding(
                        sf, e,
                        f"pytree `{cls}` puts array-annotated field "
                        f"`{e.attr}: {ann}` in aux_data — arrays belong "
                        "in children; aux is a static jit cache key"))
                elif ann and UNHASHABLE_ANN_RE.search(ann):
                    findings.append(self.finding(
                        sf, e,
                        f"pytree `{cls}` puts unhashable-annotated field "
                        f"`{e.attr}: {ann}` in aux_data — aux must be "
                        "hashable (jit cache key)"))
        # aux as a whole being a list literal (not tuple) is unhashable
        if isinstance(aux, ast.List):
            findings.append(self.finding(
                sf, aux,
                f"pytree `{cls}` aux_data is a list literal — use a "
                "tuple (aux must be hashable)"))
