"""tp-boundary: one all-reduce per TP boundary, collectives stay caged.

The TP serving contract (PR 6): each attention / MLP block ends in
exactly one all-reduce, fused into the boundary matmul via
`apply_linear(..., reduce_tp=True)` at the `wo` / `down` projection,
executed as an f32 psum before the single output cast. Extra
collectives double ICI traffic; a missing one silently de-synchronizes
shards (caught today only by the token-identity tests).

Rules:

  * in functions reachable from the shard-mapped serving step
    (`repro.models.transformer:unified_step`, plus any function marked
    `# iteralint: tp-root`), an `apply_linear` call whose weight is a
    `[...]["wo"]` / `[...]["down"]` subscript must pass
    `reduce_tp=True`;
  * no function anywhere may contain two `reduce_tp=True` call sites —
    one boundary, one reduce;
  * raw `jax.lax` collectives (psum / psum_scatter / all_gather /
    all_to_all / ppermute) are only allowed in the sanctioned wrapper
    modules (`runtime/shardctx.py`, `runtime/compression.py`) or
    lexically inside shard_map-reachable functions — anywhere else they
    execute outside a mesh axis scope and fail (or worse, run under a
    stale axis name).
"""
from __future__ import annotations

import ast

from tools.iteralint.framework import Analyzer, dotted_name

BOUNDARY_KEYS = {"wo", "down"}
COLLECTIVES = {"psum", "psum_scatter", "all_gather", "all_to_all",
               "ppermute", "pmean", "pmax", "pmin"}
SANCTIONED_MODULES = {"repro.runtime.shardctx", "repro.runtime.compression"}
SEEDS = ("repro.models.transformer:unified_step",)


def _boundary_key(call) -> str | None:
    for arg in call.args:
        if isinstance(arg, ast.Subscript) and isinstance(
                arg.slice, ast.Constant) \
                and arg.slice.value in BOUNDARY_KEYS:
            return arg.slice.value
    return None


def _has_reduce_tp(call) -> bool:
    for k in call.keywords:
        if k.arg == "reduce_tp":
            return isinstance(k.value, ast.Constant) \
                and k.value.value is True
    return False


class TPBoundaryAnalyzer(Analyzer):

    name = "tp-boundary"
    description = ("one reduce_tp per boundary function; raw collectives "
                   "only in sanctioned modules / shard_map scope")

    def run(self, project):
        graph = project.callgraph()
        findings = []
        analysis = set(project.analysis_rels)

        seeds = set(SEEDS)
        for qual, fi in graph.functions.items():
            if isinstance(fi.node, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)) \
                    and fi.sf.marker_near("tp-root", fi.node.lineno):
                seeds.add(qual)
        tp_reachable = graph.reachable_from(seeds)
        shard_scope = graph.reachable_from(graph.roots_of_kind("shard_map"))

        # rule 1: boundary projections inside the TP step must reduce.
        for qual in sorted(tp_reachable):
            fi = graph.functions[qual]
            if fi.sf.rel not in analysis:
                continue
            for call in self._own_calls(fi.node):
                fname = dotted_name(call.func) or ""
                if fname.split(".")[-1] != "apply_linear":
                    continue
                key = _boundary_key(call)
                if key is not None and not _has_reduce_tp(call):
                    findings.append(self.finding(
                        fi.sf, call,
                        f"`apply_linear` on the `{key}` boundary "
                        "projection inside the TP serving step must pass "
                        "reduce_tp=True — shards stay partial-summed "
                        "without it"))

        # rule 2 + 3 are lexical, per analyzed file.
        for sf in project.analysis_files:
            by_node = {id(fi.node): fi for fi in graph.functions.values()
                       if fi.sf is sf}
            self._lexical(sf, by_node, shard_scope, findings)
        return findings

    @staticmethod
    def _own_calls(fn):
        """Call nodes in `fn` excluding nested def/lambda bodies."""
        out = []

        def walk(node, top):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Lambda)) \
                        and not top:
                    continue
                if isinstance(child, ast.Call):
                    out.append(child)
                walk(child, False)

        walk(fn, True)
        return out

    def _lexical(self, sf, by_node, shard_scope, findings):
        stack = []

        def enclosing_quals():
            return [by_node[id(n)].qual for n in stack if id(n) in by_node]

        def walk(node):
            is_fn = isinstance(node, (ast.FunctionDef,
                                      ast.AsyncFunctionDef, ast.Lambda))
            if is_fn:
                stack.append(node)
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    n_reduce = sum(
                        1 for c in self._own_calls(node)
                        if _has_reduce_tp(c))
                    if n_reduce > 1:
                        findings.append(self.finding(
                            sf, node,
                            f"function `{node.name}` has {n_reduce} "
                            "reduce_tp=True call sites — the TP contract "
                            "is exactly one all-reduce per boundary "
                            "function"))
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.Call):
                    dn = dotted_name(child.func)
                    if dn and dn.split(".")[-1] in COLLECTIVES \
                            and ("lax" in dn.split(".")[:-1]
                                 or dn.startswith("jax.")):
                        if sf.module not in SANCTIONED_MODULES and not any(
                                q in shard_scope
                                for q in enclosing_quals()):
                            findings.append(self.finding(
                                sf, child,
                                f"raw collective `{dn}` outside the "
                                "sanctioned wrappers (runtime/shardctx, "
                                "runtime/compression) and outside any "
                                "shard_map-reachable function — use "
                                "psum_tp / tp_shard_map"))
                walk(child)
            if is_fn:
                stack.pop()

        walk(sf.tree)
