"""host-purity: the scheduler tier must not touch jax.

Admission / eviction / preemption policy runs on the host between every
engine step; importing jax there drags device runtime initialization
into scheduler unit tests and tempts device ops into the hot loop. Three
strictness levels:

  * **pure** modules (`runtime/scheduler.py`, `runtime/fault.py`, and
    any file marked `# iteralint: host-pure-module`): no jax import or
    use anywhere — not even function-local — and no top-level import of
    a first-party module that transitively imports jax at its top level;
  * **boundary** modules (`runtime/elastic.py`): the mesh-surgery half
    legitimately needs jax, but only lazily — module-level jax (or
    transitively-jax first-party) imports are flagged, function-local
    imports are fine, so `from repro.runtime.elastic import
    preemption_victims` stays jax-free;
  * **host symbols** of mixed modules (`runtime/kvblocks.py`): the
    allocator / digest half (BlockPool, blocks_needed,
    blocks_for_positions, prefix_digests, check_paged_support) must not
    reference jax names; the pool-array half may, via local imports —
    module level is held to boundary rules.

The transitive check is computed over the parsed project itself, so a
future `import repro.checkpoint.ckpt` at the top of the scheduler is
caught even though the jax import is two hops away.
"""
from __future__ import annotations

import ast

from tools.iteralint.framework import Analyzer, import_table

PURE_MODULES = {"repro.runtime.scheduler", "repro.runtime.fault"}
BOUNDARY_MODULES = {"repro.runtime.elastic", "repro.runtime.kvblocks"}
HOST_SYMBOLS = {
    "repro.runtime.kvblocks": {
        "BlockPool", "blocks_needed", "blocks_for_positions",
        "prefix_digests", "check_paged_support",
    },
}


def _toplevel_imports(tree):
    """(module, node) pairs imported at module scope (incl. try blocks)."""
    out = []
    stmts = list(tree.body)
    i = 0
    while i < len(stmts):
        node = stmts[i]
        i += 1
        if isinstance(node, ast.Try):
            stmts.extend(node.body + node.orelse + node.finalbody)
            for h in node.handlers:
                stmts.extend(h.body)
        elif isinstance(node, ast.If):
            # skip `if TYPE_CHECKING:` guards; anything else descends
            t = node.test
            name = t.id if isinstance(t, ast.Name) else (
                t.attr if isinstance(t, ast.Attribute) else None)
            if name != "TYPE_CHECKING":
                stmts.extend(node.body + node.orelse)
        elif isinstance(node, ast.Import):
            out.extend((a.name, node) for a in node.names)
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            out.append((node.module, node))
            # `from pkg import sub` may bind a submodule: record the
            # qualified name too so transitive deps resolve through it.
            out.extend((f"{node.module}.{a.name}", node)
                       for a in node.names if a.name != "*")
    return out


class HostPurityAnalyzer(Analyzer):

    name = "host-purity"
    description = ("no jax imports or device ops in host-side scheduler "
                   "modules (direct or transitive)")

    def run(self, project):
        findings = []
        jaxful = self._transitively_jaxful(project)
        for sf in project.analysis_files:
            pure = sf.module in PURE_MODULES \
                or "host-pure-module" in sf.file_markers
            boundary = sf.module in BOUNDARY_MODULES
            if pure or boundary:
                self._check_toplevel(sf, jaxful, findings)
            if pure:
                top = {id(node) for _, node in _toplevel_imports(sf.tree)}
                self._check_usage(sf, sf.tree, "module", findings, top)
            for sym in HOST_SYMBOLS.get(sf.module, ()):
                node = self._find_symbol(sf.tree, sym)
                if node is not None:
                    self._check_usage(sf, node, f"host symbol `{sym}`",
                                      findings)
        return findings

    # -- transitive first-party jax imports --------------------------------

    def _transitively_jaxful(self, project) -> set[str]:
        deps: dict[str, set[str]] = {}
        direct: set[str] = set()
        for mod, sf in project.by_module.items():
            d = set()
            for target, _ in _toplevel_imports(sf.tree):
                if target == "jax" or target.startswith("jax."):
                    direct.add(mod)
                elif target.startswith("repro."):
                    # `from repro.x import y` may name a symbol; fall back
                    # to the longest known module prefix.
                    t = target
                    while t and t not in project.by_module:
                        t = t.rpartition(".")[0]
                    if t:
                        d.add(t)
            deps[mod] = d
        jaxful = set(direct)
        changed = True
        while changed:
            changed = False
            for mod, d in deps.items():
                if mod not in jaxful and d & jaxful:
                    jaxful.add(mod)
                    changed = True
        return jaxful

    # -- checks ------------------------------------------------------------

    def _check_toplevel(self, sf, jaxful, findings):
        seen = set()
        for target, node in _toplevel_imports(sf.tree):
            if target == "jax" or target.startswith("jax."):
                if (id(node), "jax") in seen:
                    continue
                seen.add((id(node), "jax"))
                findings.append(self.finding(
                    sf, node,
                    f"host-side module imports `{target}` at module "
                    "level — import lazily inside the device-touching "
                    "function so the scheduler path stays jax-free"))
            elif target.startswith("repro."):
                t = target
                while t and t not in jaxful:
                    t = t.rpartition(".")[0]
                if t and (id(node), t) not in seen:
                    seen.add((id(node), t))
                    findings.append(self.finding(
                        sf, node,
                        f"host-side module imports `{t}`, which "
                        "transitively imports jax at module level"))

    def _check_usage(self, sf, scope, where, findings, skip=frozenset()):
        table = getattr(sf, "imports", None)
        if table is None:
            table = sf.imports = import_table(sf.tree)
        jax_aliases = {a for a, t in table.items()
                       if t == "jax" or t.startswith("jax.")}
        seen: set[str] = set()
        for node in ast.walk(scope):
            if isinstance(node, (ast.Import, ast.ImportFrom)) \
                    and id(node) not in skip:
                mods = [a.name for a in node.names] \
                    if isinstance(node, ast.Import) \
                    else [node.module or ""]
                for m in mods:
                    if m == "jax" or m.startswith("jax."):
                        findings.append(self.finding(
                            sf, node,
                            f"{where} imports `{m}` — this path must "
                            "stay host-pure"))
            elif isinstance(node, ast.Name) and node.id in jax_aliases \
                    and node.id not in seen:
                seen.add(node.id)
                findings.append(self.finding(
                    sf, node,
                    f"{where} references `{node.id}` "
                    f"(= {table[node.id]}) — this path must stay "
                    "host-pure"))
        return findings

    @staticmethod
    def _find_symbol(tree, name):
        for node in tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)) and node.name == name:
                return node
        return None
