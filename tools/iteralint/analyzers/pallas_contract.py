"""pallas-contract: static checks on every `pl.pallas_call` site.

The kernels guard their launch contracts with runtime asserts
(`choose_blocks` / `packed_pad_ok` keep them true in production); this
analyzer proves the guards are present and the specs are internally
consistent without running anything:

  * index-map arity must equal grid rank (+ num_scalar_prefetch for
    PrefetchScalarGridSpec index maps, which receive the prefetched
    scalar refs first);
  * index-map return tuple length must equal the BlockSpec block-shape
    rank;
  * accumulator scratch must not be a sub-f32 float dtype (f32 and i32
    are the MXU accumulator types; bf16/f16 scratch silently loses
    mantissa across the K loop);
  * every `dim // factor` appearing in the grid needs a matching
    `dim % factor == 0` assert in the enclosing function (BlockSpec
    shape divisibility against the declared grid);
  * a `*_packed` parameter (packed-nibble W4 path) requires a `% 256`
    lane-alignment assert mentioning it (`bn % 256`, `r % 256`);
  * when the pallas_call result is invoked inline, the positional
    operand count must match len(in_specs).
"""
from __future__ import annotations

import ast

from tools.iteralint.framework import Analyzer, dotted_name

BAD_SCRATCH_DTYPES = {"float16", "bfloat16", "float8_e4m3fn",
                      "float8_e5m2"}


def _ends_with(node, suffix):
    dn = dotted_name(node)
    return dn is not None and dn.split(".")[-1] == suffix


def _kw(call, name):
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


class _Site:
    """One pallas_call plus its resolved grid/specs/prefetch."""

    def __init__(self, call, enclosing_fn):
        self.call = call
        self.fn = enclosing_fn
        self.prefetch = 0
        grid = _kw(call, "grid")
        self.in_specs = _kw(call, "in_specs")
        self.out_specs = _kw(call, "out_specs")
        self.scratch = _kw(call, "scratch_shapes")
        spec = _kw(call, "grid_spec")
        if isinstance(spec, ast.Call):
            grid = _kw(spec, "grid") or grid
            self.in_specs = _kw(spec, "in_specs") or self.in_specs
            self.out_specs = _kw(spec, "out_specs") or self.out_specs
            self.scratch = _kw(spec, "scratch_shapes") or self.scratch
            npf = _kw(spec, "num_scalar_prefetch")
            if isinstance(npf, ast.Constant) and isinstance(npf.value, int):
                self.prefetch = npf.value
        self.grid = grid

    def grid_rank(self):
        if isinstance(self.grid, (ast.Tuple, ast.List)):
            return len(self.grid.elts)
        return None

    def blockspecs(self):
        out = []
        for container in (self.in_specs, self.out_specs):
            if container is None:
                continue
            elts = container.elts if isinstance(
                container, (ast.Tuple, ast.List)) else [container]
            for e in elts:
                if isinstance(e, ast.Call) and _ends_with(e.func,
                                                          "BlockSpec"):
                    shape = e.args[0] if e.args else _kw(e, "block_shape")
                    imap = (e.args[1] if len(e.args) > 1
                            else _kw(e, "index_map"))
                    out.append((e, shape, imap))
        return out


def _local_defs(fn):
    return {n.name: n for n in ast.walk(fn)
            if isinstance(n, ast.FunctionDef)}


def _imap_signature(imap, fn):
    """(arity, return tuple length) of an index map, best effort."""
    target = imap
    if isinstance(imap, ast.Name) and fn is not None:
        target = _local_defs(fn).get(imap.id)
    if isinstance(target, ast.Lambda):
        arity = len(target.args.posonlyargs) + len(target.args.args)
        body = target.body
        ret = len(body.elts) if isinstance(body, ast.Tuple) else 1
        return arity, ret
    if isinstance(target, ast.FunctionDef):
        arity = len(target.args.posonlyargs) + len(target.args.args)
        rets = [n.value for n in ast.walk(target)
                if isinstance(n, ast.Return) and n.value is not None]
        ret = None
        if rets:
            ret = (len(rets[0].elts)
                   if isinstance(rets[0], ast.Tuple) else 1)
        return arity, ret
    return None, None


def _assign_map(fn):
    out = {}
    if fn is None:
        return out
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt, val = node.targets[0], node.value
            if isinstance(tgt, ast.Name):
                out[tgt.id] = val
            elif isinstance(tgt, ast.Tuple) and isinstance(val, ast.Tuple) \
                    and len(tgt.elts) == len(val.elts):
                for t, v in zip(tgt.elts, val.elts):
                    if isinstance(t, ast.Name):
                        out[t.id] = v
    return out


def _mod_facts(fn):
    """(set of (a, b) `a % b` name pairs, set of names asserted % 256)."""
    pairs, mod256 = set(), set()
    if fn is None:
        return pairs, mod256
    for node in ast.walk(fn):
        if not isinstance(node, ast.Assert):
            continue
        names = {n.id for n in ast.walk(node.test)
                 if isinstance(n, ast.Name)}
        for b in ast.walk(node.test):
            if isinstance(b, ast.BinOp) and isinstance(b.op, ast.Mod):
                if isinstance(b.left, ast.Name) \
                        and isinstance(b.right, ast.Name):
                    pairs.add((b.left.id, b.right.id))
                if isinstance(b.right, ast.Constant) \
                        and b.right.value == 256:
                    mod256 |= names
    return pairs, mod256


class PallasContractAnalyzer(Analyzer):

    name = "pallas-contract"
    description = ("BlockSpec/grid consistency, scratch dtypes, "
                   "divisibility and packed-axis guards at pallas_call "
                   "sites")

    def run(self, project):
        findings = []
        for sf in project.analysis_files:
            fn_stack = []

            def walk(node):
                is_fn = isinstance(node, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
                if is_fn:
                    fn_stack.append(node)
                for child in ast.iter_child_nodes(node):
                    if isinstance(child, ast.Call) and _ends_with(
                            child.func, "pallas_call"):
                        fn = fn_stack[-1] if fn_stack else None
                        self._check_site(sf, _Site(child, fn), findings)
                    if isinstance(child, ast.Call) and isinstance(
                            child.func, ast.Call) and _ends_with(
                            child.func.func, "pallas_call"):
                        self._check_operands(sf, child, findings)
                    walk(child)
                if is_fn:
                    fn_stack.pop()

            walk(sf.tree)
        return findings

    def _check_site(self, sf, site, findings):
        rank = site.grid_rank()
        specs = site.blockspecs()
        for call, shape, imap in specs:
            arity, ret = _imap_signature(imap, site.fn)
            if rank is not None and arity is not None:
                want = rank + site.prefetch
                if arity != want:
                    expect = (f"rank {rank} + {site.prefetch} "
                              f"scalar-prefetch refs = {want}"
                              if site.prefetch else f"rank {rank}")
                    findings.append(self.finding(
                        sf, call,
                        f"BlockSpec index map takes {arity} args but the "
                        f"grid has {expect}"))
            if ret is not None and isinstance(shape, (ast.Tuple, ast.List)):
                if ret != len(shape.elts):
                    findings.append(self.finding(
                        sf, call,
                        f"BlockSpec index map returns {ret} coordinates "
                        f"for a rank-{len(shape.elts)} block shape"))
        self._check_scratch(sf, site, findings)
        self._check_divisibility(sf, site, findings)
        self._check_packed(sf, site, findings)

    def _check_scratch(self, sf, site, findings):
        if site.scratch is None:
            return
        elts = site.scratch.elts if isinstance(
            site.scratch, (ast.Tuple, ast.List)) else [site.scratch]
        for e in elts:
            if not (isinstance(e, ast.Call) and _ends_with(e.func, "VMEM")):
                continue
            for arg in e.args[1:] + [k.value for k in e.keywords]:
                dn = dotted_name(arg)
                if dn and dn.split(".")[-1] in BAD_SCRATCH_DTYPES:
                    findings.append(self.finding(
                        sf, e,
                        f"accumulator scratch declared {dn.split('.')[-1]}"
                        " — accumulate in f32/i32 and cast once on the "
                        "final K step"))

    def _check_divisibility(self, sf, site, findings):
        if not isinstance(site.grid, (ast.Tuple, ast.List)):
            return
        assigns = _assign_map(site.fn)
        pairs, _ = _mod_facts(site.fn)

        def div_pairs(expr, depth=0):
            if depth > 3:
                return
            if isinstance(expr, ast.Name) and expr.id in assigns:
                yield from div_pairs(assigns[expr.id], depth + 1)
            elif isinstance(expr, ast.BinOp):
                if isinstance(expr.op, ast.FloorDiv) and isinstance(
                        expr.left, ast.Name) and isinstance(
                        expr.right, ast.Name):
                    yield expr.left.id, expr.right.id
                else:
                    yield from div_pairs(expr.left, depth + 1)
                    yield from div_pairs(expr.right, depth + 1)

        for elt in site.grid.elts:
            for dim, factor in div_pairs(elt):
                if (dim, factor) not in pairs:
                    findings.append(self.finding(
                        sf, site.call,
                        f"grid divides `{dim} // {factor}` but the kernel "
                        f"wrapper never asserts `{dim} % {factor} == 0` — "
                        "a ragged tail block reads out of bounds"))

    def _check_packed(self, sf, site, findings):
        if site.fn is None:
            return
        params = [p.arg for p in site.fn.args.posonlyargs
                  + site.fn.args.args + site.fn.args.kwonlyargs]
        packed = [p for p in params if "packed" in p]
        if not packed:
            return
        _, mod256 = _mod_facts(site.fn)
        for p in packed:
            if p not in mod256:
                findings.append(self.finding(
                    sf, site.fn,
                    f"kernel wrapper takes packed flag `{p}` but has no "
                    "`% 256` lane-alignment assert mentioning it (packed "
                    "int4 pairs two values per int8 lane; the packed "
                    "block axis must stay a multiple of 256)"))

    def _check_operands(self, sf, outer, findings):
        if any(isinstance(a, ast.Starred) for a in outer.args):
            return
        site = _Site(outer.func, None)
        if site.in_specs is None or not isinstance(
                site.in_specs, (ast.Tuple, ast.List)):
            return
        n_specs = len(site.in_specs.elts)
        if len(outer.args) != n_specs:
            findings.append(self.finding(
                sf, outer,
                f"pallas_call declares {n_specs} in_specs but is invoked "
                f"with {len(outer.args)} positional operands"))
