"""Quickstart: ITERA-LLM in ~60 seconds on CPU.

1. Build a weight matrix with LLM-like structure (decaying spectrum +
   outliers) and show Algorithm 1 beating one-shot SVD+quant at W4.
2. Compress a whole (smoke-size) model through per-layer CompressionPlans
   — uniform quant / svd / itera, plus a mixed W4-attention / W8-MLP plan
   the legacy single-method config could not express.
3. Serve the compressed model through the InferenceEngine facade.
4. Run the fused cascade Pallas kernel (interpret mode) against its oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.api import CompressionPlan, InferenceEngine, LayerPlan, SamplingParams
from repro.configs import get_config
from repro.core import (
    compress_params, itera_decompose, reconstruction_error, svd_decompose,
)
from repro.kernels import ops
from repro.models import init_params
from repro.models.transformer import forward


def llm_like(key, k, n):
    ku, kv, ko = jax.random.split(key, 3)
    u = jax.random.normal(ku, (k, min(k, n)))
    v = jax.random.normal(kv, (min(k, n), n))
    s = jnp.exp(-0.02 * jnp.arange(min(k, n)))
    return (u * s) @ v + jax.random.bernoulli(ko, 0.001, (k, n)) * 10.0


def main():
    key = jax.random.PRNGKey(0)

    print("== 1. Algorithm 1 vs one-shot SVD+quant (W4, rank 128) ==")
    w = llm_like(key, 512, 512)
    for rank in (64, 128, 256):
        e_it = float(reconstruction_error(w, itera_decompose(w, rank, 4)))
        e_sv = float(reconstruction_error(w, svd_decompose(w, rank, 4)))
        print(f"  rank {rank:3d}:  itera {e_it:.4f}   svd+quant {e_sv:.4f}"
              f"   ({100 * (e_sv - e_it) / e_sv:+.1f}% better)")

    print("== 2. Whole-model plans (opus-mt smoke) ==")
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    h_ref, _ = forward(params, toks, cfg)

    plans = [CompressionPlan.uniform(params, method=m, weight_wl=4,
                                     rank_fraction=0.5)
             for m in ("quant", "svd", "itera")]
    itera_plan = plans[-1]
    # mixed precision: W4 attention, W8 MLP — a per-layer decision only a
    # plan (not the legacy uniform config) can express.
    plans.append(itera_plan.replace(
        label="itera_W4attn_W8mlp",
        layers=tuple(
            LayerPlan(lp.path, "itera", 4 if "attn" in lp.path else 8,
                      lp.rank)
            for lp in itera_plan.layers)))
    for plan in plans:
        cp, rep = compress_params(params, plan)
        h, _ = forward(cp, toks, cfg)
        dist = float(jnp.linalg.norm(h - h_ref) / jnp.linalg.norm(h_ref))
        print(f"  {plan.label:18s}: {rep.summary()}  output-dist={dist:.4f}")

    print("== 3. Serve the mixed plan through the engine facade ==")
    engine = InferenceEngine.build(cfg, plans[-1], params=params)
    res = engine.generate(toks[:, :16], SamplingParams(max_tokens=8))
    print(f"  generated {res.tokens.shape} "
          f"({res.tokens_per_second:.1f} tok/s): {res.tokens[0].tolist()}")

    print("== 4. Fused cascade kernel vs oracle (interpret mode) ==")
    x = jax.random.normal(key, (64, 512))
    lr = itera_decompose(llm_like(key, 512, 512) / 22.0, 128, 6)
    y_k = ops.lrmm(x, lr, use_kernel=True, interpret=True)
    y_r = ops.lrmm(x, lr, use_kernel=False)
    print(f"  kernel vs oracle max|diff| = "
          f"{float(jnp.max(jnp.abs(y_k - y_r))):.2e}")
    print("done.")


if __name__ == "__main__":
    main()
