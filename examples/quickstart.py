"""Quickstart: ITERA-LLM in ~60 seconds on CPU.

1. Build a weight matrix with LLM-like structure (decaying spectrum +
   outliers) and show Algorithm 1 beating one-shot SVD+quant at W4.
2. Compress a whole (smoke-size) model with quant / svd / itera and
   compare storage ratio, NOps, and output distortion.
3. Run the fused cascade Pallas kernel (interpret mode) against its oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    CompressionConfig, compress_params, itera_decompose,
    reconstruction_error, svd_decompose,
)
from repro.kernels import ops
from repro.models import init_params
from repro.models.transformer import forward


def llm_like(key, k, n):
    ku, kv, ko = jax.random.split(key, 3)
    u = jax.random.normal(ku, (k, min(k, n)))
    v = jax.random.normal(kv, (min(k, n), n))
    s = jnp.exp(-0.02 * jnp.arange(min(k, n)))
    return (u * s) @ v + jax.random.bernoulli(ko, 0.001, (k, n)) * 10.0


def main():
    key = jax.random.PRNGKey(0)

    print("== 1. Algorithm 1 vs one-shot SVD+quant (W4, rank 128) ==")
    w = llm_like(key, 512, 512)
    for rank in (64, 128, 256):
        e_it = float(reconstruction_error(w, itera_decompose(w, rank, 4)))
        e_sv = float(reconstruction_error(w, svd_decompose(w, rank, 4)))
        print(f"  rank {rank:3d}:  itera {e_it:.4f}   svd+quant {e_sv:.4f}"
              f"   ({100 * (e_sv - e_it) / e_sv:+.1f}% better)")

    print("== 2. Whole-model compression (opus-mt smoke) ==")
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    h_ref, _ = forward(params, toks, cfg)
    for method in ("quant", "svd", "itera"):
        cp, rep = compress_params(params, CompressionConfig(
            method=method, weight_wl=4, rank_fraction=0.5))
        h, _ = forward(cp, toks, cfg)
        dist = float(jnp.linalg.norm(h - h_ref) / jnp.linalg.norm(h_ref))
        print(f"  {method:6s}: {rep.summary()}  output-dist={dist:.4f}")

    print("== 3. Fused cascade kernel vs oracle (interpret mode) ==")
    x = jax.random.normal(key, (64, 512))
    lr = itera_decompose(llm_like(key, 512, 512) / 22.0, 128, 6)
    y_k = ops.lrmm(x, lr, use_kernel=True, interpret=True)
    y_r = ops.lrmm(x, lr, use_kernel=False)
    print(f"  kernel vs oracle max|diff| = "
          f"{float(jnp.max(jnp.abs(y_k - y_r))):.2e}")
    print("done.")


if __name__ == "__main__":
    main()
