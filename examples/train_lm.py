"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on the synthetic Markov stream, with checkpointing and fault tolerance.

By default this trains a 12-layer / d=768 decoder (~103M params) for 200
steps — sized for a CPU session (use --steps 500 on a beefier host). The
same entry point scales to the pod configs via --arch.

    PYTHONPATH=src python examples/train_lm.py [--tiny]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs.base import ModelConfig                    # noqa: E402
from repro.data.pipeline import LatentMarkovTask, shard_batch  # noqa: E402
from repro.models import transformer as tfm                   # noqa: E402
from repro.optim import adamw                                 # noqa: E402
from repro.checkpoint import ckpt as ckpt_lib                 # noqa: E402
from repro.runtime.fault import ResilientLoop                 # noqa: E402


def lm_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", layout="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=8192,
        mlp_act="swiglu", dtype="float32", remat=False, loss_chunk=512,
    )


def lm_tiny() -> ModelConfig:
    return ModelConfig(
        name="lm-tiny", layout="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, d_ff=1024, vocab_size=2048,
        mlp_act="swiglu", dtype="float32", remat=False, loss_chunk=256,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="4L/256d variant for quick demos")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/itera_lm_ckpt")
    args = ap.parse_args(argv)

    cfg = lm_tiny() if args.tiny else lm_100m()
    n_params = cfg.param_count()
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps of batch {args.batch} x seq {args.seq}")

    task = LatentMarkovTask(cfg.vocab_size, seed=0, branching=8, classes=64)
    key = jax.random.PRNGKey(0)
    params = tfm.init_params(key, cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 10, 5))
    opt = adamw.init(params, opt_cfg)

    @jax.jit
    def train_step(params, opt, batch):
        (loss, m), g = jax.value_and_grad(tfm.loss_fn, has_aux=True)(
            params, batch, cfg)
        p, o, om = adamw.update(g, opt, params, opt_cfg)
        return p, o, {"loss": loss, **om}

    def step_fn(state, step):
        p, o, metrics = train_step(state["params"], state["opt"],
                                   task.batch(step, args.batch, args.seq))
        if step % 20 == 0:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        return {"params": p, "opt": o}, metrics

    def save_fn(state, step):
        ckpt_lib.save(args.ckpt_dir, step, state, async_save=True)

    def restore_fn():
        like = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        return ckpt_lib.restore(args.ckpt_dir, like)

    state = {"params": params, "opt": opt}
    loop = ResilientLoop(step_fn, save_fn, restore_fn, ckpt_every=100)
    state, _ = loop.run(state, 0, args.steps)

    losses = loop.report.losses
    k = max(len(losses) // 10, 1)
    print(f"[train_lm] loss: first {np.mean(losses[:k]):.4f} -> "
          f"last {np.mean(losses[-k:]):.4f} "
          f"(entropy floor {task.entropy_floor():.4f})")
    ckpt_lib.save(args.ckpt_dir, args.steps, state)
    print(f"[train_lm] checkpoint at {args.ckpt_dir}")
    return losses


if __name__ == "__main__":
    main()
