"""Hardware-aware DSE walkthrough (paper §VII) on both platform models.

Explores engine/tile configurations for the paper's 512x512x512 workload
on the faithful ZCU111 model, then runs the TPU-model co-design loop over
compression candidates and prints the accuracy-latency Pareto points.

    PYTHONPATH=src python examples/dse_explore.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.hw import engine_model as em                       # noqa: E402
from repro.hw import tpu_model as tm                          # noqa: E402


def main():
    m = k = n = 512
    r = 128

    print("== ZCU111 (paper eqs. 12-19), 512^3 W4A8, rank 128 ==")
    pts = em.explore(m, k, n, r, weight_wl=4)
    for kind in ("baseline", "single", "cascade"):
        front = em.pareto_front([p for p in pts if p.kind == kind])
        best = min(front, key=lambda p: p.latency_cycles)
        print(f"  {kind:8s}: best {best.latency_cycles/200e3:.2f} ms "
              f"@ {best.bandwidth:.0f} bits/cyc, DSP {best.dsp}, "
              f"BRAM {best.bram}  (front: {len(front)} pts)")

    print("== TPU v5e model, same workload ==")
    for bw_scale, regime in ((1.0, "full-bandwidth"),
                             (0.25, "quarter-bandwidth")):
        row = []
        for kind, engines in (("baseline", ("baseline",)),
                              ("single", ("single",)),
                              ("cascade", ("cascade",))):
            p = tm.best_point(m, k, n, r, weight_wl=4,
                              hbm_bw=tm.HBM_BW * bw_scale, engines=engines)
            row.append(f"{kind} {p.latency_s*1e6:.2f}us"
                       f"[{'C' if p.compute_s >= p.memory_s else 'M'}]")
        print(f"  {regime:18s}: " + "  ".join(row))

    print("== per-layer engine choice for an OPUS-MT-like stack ==")
    layers = [("qkv", 512, 512, 128), ("ffn_up", 512, 2048, 192),
              ("ffn_dn", 2048, 512, 192)]
    for name, kk, nn, rr in layers:
        best = tm.best_point(512, kk, nn, rr, weight_wl=4)
        print(f"  {name:8s}: {best.kind:8s} {best.latency_s*1e6:8.2f} us  "
              f"blocks {best.config['blocks']}")


if __name__ == "__main__":
    main()
