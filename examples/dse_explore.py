"""Hardware-aware DSE walkthrough (paper §VII) on both platform models,
ending at deployment: explore engine/tile configurations, run the co-design
loop over CompressionPlan candidates, pick a Pareto design point, and serve
it through the InferenceEngine — the full plan→engine seam in one script.

    PYTHONPATH=src python examples/dse_explore.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

from repro.api import (                                       # noqa: E402
    CompressionPlan, InferenceEngine, SamplingParams,
)
from repro.configs import get_config                          # noqa: E402
from repro.core.compress import compress_params               # noqa: E402
from repro.hw import dse                                      # noqa: E402
from repro.hw import engine_model as em                       # noqa: E402
from repro.hw import tpu_model as tm                          # noqa: E402
from repro.models import init_params                          # noqa: E402
from repro.models.transformer import forward                  # noqa: E402


def main():
    m = k = n = 512
    r = 128

    print("== ZCU111 (paper eqs. 12-19), 512^3 W4A8, rank 128 ==")
    pts = em.explore(m, k, n, r, weight_wl=4)
    for kind in ("baseline", "single", "cascade"):
        front = em.pareto_front([p for p in pts if p.kind == kind])
        best = min(front, key=lambda p: p.latency_cycles)
        print(f"  {kind:8s}: best {best.latency_cycles/200e3:.2f} ms "
              f"@ {best.bandwidth:.0f} bits/cyc, DSP {best.dsp}, "
              f"BRAM {best.bram}  (front: {len(front)} pts)")

    print("== TPU v5e model, same workload ==")
    for bw_scale, regime in ((1.0, "full-bandwidth"),
                             (0.25, "quarter-bandwidth")):
        row = []
        for kind, engines in (("baseline", ("baseline",)),
                              ("single", ("single",)),
                              ("cascade", ("cascade",))):
            p = tm.best_point(m, k, n, r, weight_wl=4,
                              hbm_bw=tm.HBM_BW * bw_scale, engines=engines)
            row.append(f"{kind} {p.latency_s*1e6:.2f}us"
                       f"[{'C' if p.compute_s >= p.memory_s else 'M'}]")
        print(f"  {regime:18s}: " + "  ".join(row))

    print("== co-design over CompressionPlan candidates (opus-mt smoke) ==")
    cfg = get_config("opus-mt", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                              cfg.vocab_size)
    h_ref, _ = forward(params, toks, cfg)

    candidates = [
        CompressionPlan.uniform(params, method="quant", weight_wl=wl)
        for wl in (8, 4)
    ] + [
        CompressionPlan.uniform(params, method="itera", weight_wl=wl,
                                rank_fraction=frac,
                                label=f"itera_W{wl}_f{frac}")
        for wl in (8, 4) for frac in (0.5, 0.35)
    ]

    def quality(plan):
        cp, rep = compress_params(params, plan)
        h, _ = forward(cp, toks, cfg)
        plan.meta["ratio"] = rep.compression_ratio
        plan.meta["nops"] = rep.nops_per_row
        return -float(jnp.linalg.norm(h - h_ref) / jnp.linalg.norm(h_ref))

    front = dse.co_design(candidates, quality, params=params, batch_m=512,
                          bw_scale=0.25)
    for dp in front:
        print(f"  pareto: {dp.label:14s} quality {dp.quality:+.4f} "
              f"latency {dp.latency*1e6:8.2f} us "
              f"ratio {dp.compression_ratio:.1f}x")

    print("== deploy the best design point through the engine ==")
    best = front[-1]                       # highest quality on the front
    plan = CompressionPlan.from_design_point(best)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "plan.json")
        plan.save(path)                    # what serve --plan consumes
        engine = InferenceEngine.build(cfg, CompressionPlan.load(path),
                                       params=params)
    res = engine.generate(toks[:, :16], SamplingParams(max_tokens=8))
    print(f"  {plan.summary()}")
    print(f"  generated {res.tokens.shape}: {res.tokens[0].tolist()}")


if __name__ == "__main__":
    main()
