"""The full ITERA-LLM post-training pipeline on one screen:

  train (or load) -> compress (uniform plan | SRA per-layer ranks) ->
  serve through the InferenceEngine facade -> compare quality & cost.

    PYTHONPATH=src python examples/compress_and_serve.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

import numpy as np                                            # noqa: E402

from common import DecompCache, token_accuracy, train_proxy   # noqa: E402
from repro.api import (                                       # noqa: E402
    CompressionPlan, InferenceEngine, SamplingParams,
)
from repro.core.compress import CompressionConfig             # noqa: E402
from repro.core.sra import sra_allocate, uniform_allocation   # noqa: E402


def main():
    params, cfg, task = train_proxy()
    base_acc = token_accuracy(params, cfg, task)
    print(f"[pipeline] fp32 accuracy {base_acc:.4f}")

    wl = 4
    dc = DecompCache(params, CompressionConfig(method="itera", weight_wl=wl))
    L = dc.num_layers
    full = max(dc.max_rank(p) for p in dc.targets)
    budget = int(L * full * 0.5)

    # uniform-rank ITERA as a serializable plan (JSON round-trip included)
    uni = uniform_allocation(L, budget, [full] * L)
    plan = CompressionPlan.uniform(params, method="itera", weight_wl=wl,
                                   rank_fraction=uni[0] / full,
                                   label=f"itera_W{wl}_uniform")
    plan = CompressionPlan.loads(plan.dumps())   # what serve --plan consumes
    acc_uni = token_accuracy(dc.compressed_params(params, uni, "itera"),
                             cfg, task)
    ratio, nops, dense = dc.accounting(uni, "itera")
    print(f"[pipeline] {plan.summary()}: acc {acc_uni:.4f} "
          f"ratio {ratio:.1f}x NOps -{100*(1-nops/dense):.0f}%")

    # SRA-allocated ranks (paper §IV)
    def ev(ranks):
        cp = dc.compressed_params(params, list(ranks), "itera")
        return token_accuracy(cp, cfg, task, batches=2)

    res = sra_allocate(ev, L, budget, [full] * L,
                       delta0=max(1, full // 8), max_iters=10, patience=4)
    acc_sra = token_accuracy(dc.compressed_params(params, res.ranks,
                                                  "itera"), cfg, task)
    print(f"[pipeline] itera W{wl} SRA ranks {res.ranks}: acc {acc_sra:.4f} "
          f"({res.evals} calibration evals)")

    # serve both models through the engine facade
    cp = dc.compressed_params(params, res.ranks, "itera")
    dense_eng = InferenceEngine(cfg, params)
    comp_eng = InferenceEngine(cfg, cp)
    prompts = task.batch(99_999, 4, 32)["tokens"]
    sampling = SamplingParams(max_tokens=16)
    dense_toks = dense_eng.generate(prompts, sampling).tokens
    comp_res = comp_eng.generate(prompts, sampling)
    agree = float(np.mean(dense_toks == comp_res.tokens))
    print(f"[pipeline] greedy decode agreement vs fp32: {agree:.2%} "
          f"({comp_res.tokens_per_second:.1f} tok/s compressed)")
    print("[pipeline] sample (compressed):",
          comp_res.tokens[0][:12].tolist())


if __name__ == "__main__":
    main()
