"""The full ITERA-LLM post-training pipeline on one screen:

  train (or load) -> compress (quant | svd | itera, + SRA ranks) ->
  serve (prefill + batched greedy decode) -> compare quality & cost.

    PYTHONPATH=src python examples/compress_and_serve.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

import jax                                                    # noqa: E402
import numpy as np                                            # noqa: E402

from common import DecompCache, token_accuracy, train_proxy   # noqa: E402
from repro.core.compress import CompressionConfig             # noqa: E402
from repro.core.sra import sra_allocate, uniform_allocation   # noqa: E402
from repro.launch.serve import generate                       # noqa: E402


def main():
    params, cfg, task = train_proxy()
    base_acc = token_accuracy(params, cfg, task)
    print(f"[pipeline] fp32 accuracy {base_acc:.4f}")

    wl = 4
    dc = DecompCache(params, CompressionConfig(method="itera", weight_wl=wl))
    L = dc.num_layers
    full = max(dc.max_rank(p) for p in dc.targets)
    budget = int(L * full * 0.5)

    # uniform-rank ITERA
    uni = uniform_allocation(L, budget, [full] * L)
    acc_uni = token_accuracy(dc.compressed_params(params, uni, "itera"),
                             cfg, task)
    ratio, nops, dense = dc.accounting(uni, "itera")
    print(f"[pipeline] itera W{wl} uniform ranks {uni}: acc {acc_uni:.4f} "
          f"ratio {ratio:.1f}x NOps -{100*(1-nops/dense):.0f}%")

    # SRA-allocated ranks (paper §IV)
    def ev(ranks):
        cp = dc.compressed_params(params, list(ranks), "itera")
        return token_accuracy(cp, cfg, task, batches=2)

    res = sra_allocate(ev, L, budget, [full] * L,
                       delta0=max(1, full // 8), max_iters=10, patience=4)
    acc_sra = token_accuracy(dc.compressed_params(params, res.ranks,
                                                  "itera"), cfg, task)
    print(f"[pipeline] itera W{wl} SRA ranks {res.ranks}: acc {acc_sra:.4f} "
          f"({res.evals} calibration evals)")

    # serve with the SRA-compressed model
    cp = dc.compressed_params(params, res.ranks, "itera")
    prompts = task.batch(99_999, 4, 32)["tokens"]
    dense_toks = generate(params, cfg, prompts, 16)
    comp_toks = generate(cp, cfg, prompts, 16)
    agree = float(np.mean(np.asarray(dense_toks) == np.asarray(comp_toks)))
    print(f"[pipeline] greedy decode agreement vs fp32: {agree:.2%}")
    print("[pipeline] sample (compressed):",
          np.asarray(comp_toks[0][:12]).tolist())


if __name__ == "__main__":
    main()
